"""Fused masked-aggregation entry points: the round engine's fast path.

Each fused aggregator is a drop-in twin of its ``aggregation.masked_*``
counterpart — same name, same keyword surface — that additionally accepts
a node-batched :class:`~repro.kernels.qsgd_decode.ops.QsgdPayload` in
place of the fp32 (N, D) stack, so a compressed round feeds wire payloads
straight into aggregation.

Two implementations sit behind each twin:

- ``use_kernel=False`` (the default off-TPU): restructured jnp with
  **identical op-level arithmetic** to the reference, so fused == unfused
  bit-for-bit (pinned by tests/test_kernel_conformance.py).  The speed
  comes from two algorithm swaps, not looser numerics:
  (1) the coordinate-median warm start runs as a Batcher odd-even merge
  network over the N node rows — pure min/max, bit-equal to ``nanmedian``
  including its even-k interpolation, and ~6x faster than XLA's generic
  sort of the (N, D) stack at N=16, D=1M on CPU;
  (2) krum's pairwise distances accumulate in gram form
  (‖xᵢ‖² + ‖xⱼ‖² − 2·xᵢᵀxⱼ, one matmul) instead of the broadcast
  (N, N, D) difference tensor (~15x).  Gram d2 is *not* bit-equal to the
  broadcast d2 (cancellation at ~1e-6 relative), but krum's output is an
  argmin **selection** — equal except at exact score ties.
- ``use_kernel=True`` (auto on TPU backends): the Pallas kernels from
  ``kernel.py``, which additionally keep every D-sized intermediate in
  VMEM tiles.  Tiled norm accumulation reorders float sums, so the kernel
  path carries the same documented ~1e-5 relative divergence as the
  centralized centered_clip kernel.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.kernels.masked_agg import kernel as _k
from repro.kernels.qsgd_decode import ops as qdec

Array = jax.Array

# make_round_fn auto-selects the fused path once the fp32 update stack
# (N·D·4 bytes) crosses this; below it the unfused path compiles faster and
# the sort being replaced is already cheap.
FUSED_MIN_BYTES = 4 << 20


def _auto_kernel(use_kernel: Optional[bool]) -> bool:
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return use_kernel


def _as_f32_stack(updates) -> Array:
    """(N, D) f32 view of either a dense stack or a QsgdPayload batch."""
    if isinstance(updates, qdec.QsgdPayload):
        return qdec.wire_decode(updates)
    return updates.astype(jnp.float32)


def masked_median_net(updates: Array, mask: Array) -> Array:
    """Masked coordinate median via the odd-even merge network — bit-equal
    to ``aggregation._masked_median`` for mask.sum() >= 1."""
    n = updates.shape[0]
    rows = [jnp.where(mask[i], updates[i], jnp.inf) for i in range(n)]
    k = jnp.sum(mask.astype(jnp.int32))
    return _k._masked_rank_interp(_k._sorted_rows(rows), k)


def masked_centered_clip_fused(updates, mask: Array, *,
                               clip_tau=None, iters: int = 3, v0=None,
                               use_kernel: Optional[bool] = None,
                               block_d: int = 2048,
                               interpret: bool = False) -> Array:
    x = _as_f32_stack(updates)
    if _auto_kernel(use_kernel):
        v = (v0.astype(jnp.float32) if v0 is not None
             else _k.masked_median_fwd(x, mask, block_d=block_d,
                                       interpret=interpret))
        for _ in range(iters):
            v = _k.masked_cc_iter_fwd(x, v, mask, clip_tau=clip_tau,
                                      block_d=block_d, interpret=interpret)
        out = v
    else:
        warm = v0 if v0 is not None else masked_median_net(x, mask)
        # delegate to the reference with the network warm start — every
        # iteration op is then literally the reference's, hence bit-equal
        out = aggregation.masked_centered_clip(
            x, mask, clip_tau=clip_tau, iters=iters, v0=warm)
    return jnp.where(jnp.any(mask), out, jnp.zeros_like(out))


def masked_krum_fused(updates, mask: Array, *, f: int = 1,
                      use_kernel: Optional[bool] = None,
                      block_d: int = 2048,
                      interpret: bool = False) -> Array:
    x = _as_f32_stack(updates)
    if _auto_kernel(use_kernel):
        d2 = _k.masked_krum_d2_fwd(x, block_d=block_d, interpret=interpret)
    else:
        sq = jnp.sum(x * x, axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    scores = aggregation._krum_scores_from_d2(d2, mask, f)
    row = x[jnp.argmin(scores)]
    return jnp.where(jnp.any(mask), row, jnp.zeros_like(row))


def masked_mean_fused(updates, mask: Array, *,
                      use_kernel: Optional[bool] = None,
                      block_d: int = 4096,
                      interpret: bool = False) -> Array:
    if isinstance(updates, qdec.QsgdPayload):
        k = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
        acc = qdec.decode_accumulate(
            updates, mask.astype(jnp.float32),
            use_kernel=_auto_kernel(use_kernel), block_d=block_d,
            interpret=interpret)
        return acc / k
    return aggregation.masked_mean(updates, mask)


FUSED_MASKED_AGGREGATORS: Dict[str, Callable] = {
    "mean": masked_mean_fused,
    "krum": masked_krum_fused,
    "centered_clip": masked_centered_clip_fused,
}


def get_fused_aggregator(name: str, **defaults) -> Callable:
    """Fused twin of ``aggregation.get_masked_aggregator`` — same names,
    same keyword routing; raises KeyError for aggregators without a fused
    implementation (the engine falls back to the unfused path)."""
    fn = FUSED_MASKED_AGGREGATORS[name]
    return functools.partial(fn, **defaults) if defaults else fn
