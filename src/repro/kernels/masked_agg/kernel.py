"""Fused masked robust aggregation — Pallas TPU kernels (paper §3.3 hot path).

The swarm round's aggregation phase consumes the (N, D) submitted-update
stack with the active-mask folded in (``keep = active & ~caught``).  The
unfused path materializes several stack-sized intermediates per round —
most expensively the coordinate-median warm start (a full sort of the
stack) and CenteredClip's per-iteration ``diff``/``scale`` arrays.  These
kernels stream D in VMEM tiles so nothing of size D beyond the stack
itself round-trips through HBM:

- ``masked_median_fwd`` — the masked coordinate-median warm start.  Columns
  are independent, so each (N, block_d) tile is sorted **in VMEM** by a
  Batcher odd-even merge network over the node rows (N is small; the
  network is generated statically in Python and unrolled as vectorized
  min/max pairs).  Masked rows are +inf-padded; the two middle ranks of
  the *kept* count k (a traced scalar — churn never retraces) are selected
  arithmetically and averaged, which reproduces ``nanmedian``'s
  interpolation bit-for-bit.
- ``masked_cc_iter_fwd`` — one CenteredClip iteration, flash-style
  two-phase grid (phase 0 accumulates per-node squared norms into a
  persistent (N, 1) VMEM scratch; phase 1 re-streams the tiles and applies
  the masked clipped mean).  Extends the centralized ``centered_clip``
  kernel with the keep-mask and the engine's default **adaptive τ** (the
  masked median of the per-node distances, computed in-kernel from the
  norm scratch by the same sorting network).
- ``masked_krum_d2_fwd`` — krum's pairwise-distance phase.  Streams D
  tiles and accumulates the (N, N) squared-distance matrix via the gram
  form ``|x_i|² + |x_j|² − 2·x_iᵀx_j`` (one MXU matmul per tile) into a
  revisited output block.  The O(N²) selection phase is left to plain jnp
  in ops.py — it touches nothing of size D.

Grids: median/krum (n_d_blocks,); CC (2, n_d_blocks) phase-outermost.
All kernels carry an ``interpret=True`` path so tier-1 pins them on CPU.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def oddeven_merge_pairs(n: int) -> List[Tuple[int, int]]:
    """Compare-exchange pairs of Batcher's odd-even merge sort for ``n`` a
    power of two.  Sorting is pure min/max — no arithmetic — so a network
    sort equals ``jnp.sort`` exactly, while vectorizing over the lane
    dimension instead of paying XLA's generic sort."""
    if n & (n - 1):
        raise ValueError(f"network size must be a power of two, got {n}")
    pairs: List[Tuple[int, int]] = []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            for j in range(k % p, n - k, 2 * k):
                for i in range(min(k, n - j - k)):
                    if (i + j) // (p * 2) == (i + j + k) // (p * 2):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return pairs


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


LANE = 128


def _pad_lanes(x, *, mult: int = LANE):
    """Zero-pad the trailing (feature) dim to a lane multiple.  Zero columns
    are exact no-ops for every kernel here — they contribute 0 to squared
    norms and pairwise distances, and median/CC outputs are sliced back —
    whereas letting block_d degenerate toward 1 both wastes the VPU and
    (observed in interpret mode) reorders accumulation enough to break
    d2's symmetry at the last ulp."""
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def _fit_block(d: int, block_d: int) -> int:
    """Largest lane-multiple tile <= block_d that divides d (d is already a
    lane multiple, so this bottoms out at LANE)."""
    block_d = max(LANE, min(block_d, d) // LANE * LANE)
    while d % block_d:
        block_d -= LANE
    return block_d


def _sorted_rows(rows: List[jax.Array]) -> List[jax.Array]:
    """Apply the odd-even network to a list of equal-shaped rows (+inf rows
    pad to the next power of two); returns the rows in ascending order."""
    n = len(rows)
    npad = _next_pow2(n)
    rows = rows + [jnp.full_like(rows[0], jnp.inf)] * (npad - n)
    for i, j in oddeven_merge_pairs(npad):
        a, b = rows[i], rows[j]
        rows[i], rows[j] = jnp.minimum(a, b), jnp.maximum(a, b)
    return rows[:n]


def _masked_rank_interp(rows: List[jax.Array], k: jax.Array) -> jax.Array:
    """(lo + hi) / 2 of the two middle ranks of the first k sorted rows —
    nanmedian's even/odd interpolation with a *traced* kept-count k."""
    lo_idx = (k - 1) // 2
    hi_idx = k // 2
    lo = rows[0] * 0.0
    hi = rows[0] * 0.0
    for r, row in enumerate(rows):
        lo = lo + jnp.where(r == lo_idx, row, 0.0)
        hi = hi + jnp.where(r == hi_idx, row, 0.0)
    return (lo + hi) * 0.5


# ---------------------------- masked median ------------------------------------
def _median_kernel(x_ref, m_ref, o_ref, *, n: int):
    m = m_ref[...].astype(jnp.float32)                     # (N, 1)
    k = jnp.sum(m).astype(jnp.int32)
    rows = [jnp.where(m[i, 0] > 0,
                      x_ref[i:i + 1, :].astype(jnp.float32),
                      jnp.inf)
            for i in range(n)]
    o_ref[...] = _masked_rank_interp(_sorted_rows(rows), k)


def masked_median_fwd(updates, mask, *, block_d: int = 2048,
                      interpret: bool = False):
    """Masked coordinate median.  updates (N, D) f32, mask (N,) -> (D,).
    Bit-equal to ``aggregation._masked_median`` for k >= 1 (all-masked
    columns are meaningless — callers guard k == 0)."""
    n, d0 = updates.shape
    updates, _ = _pad_lanes(updates)
    d = updates.shape[1]
    block_d = _fit_block(d, block_d)
    kern = functools.partial(_median_kernel, n=n)
    out = pl.pallas_call(
        kern,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(updates, mask.reshape(n, 1).astype(jnp.float32))
    return out.reshape(d)[:d0]


# --------------------------- masked CenteredClip -------------------------------
def _cc_kernel(x_ref, v_ref, m_ref, o_ref, sq_ref, *, n: int, tau):
    """tau: static float for fixed-τ, or None for the adaptive masked-median
    τ recomputed per phase-1 tile from the completed norm scratch."""
    ph = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = x_ref[...].astype(jnp.float32) - v_ref[...].astype(jnp.float32)

    @pl.when(ph == 0)
    def _accumulate():
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)
        o_ref[...] = v_ref[...]                        # placeholder write

    @pl.when(ph == 1)
    def _apply():
        m = m_ref[...].astype(jnp.float32)             # (N, 1)
        k = jnp.maximum(jnp.sum(m), 1.0)
        norm = jnp.sqrt(sq_ref[...])                   # (N, 1)
        if tau is None:
            kept = jnp.sum(m).astype(jnp.int32)
            rows = [jnp.where(m[i, 0] > 0, norm[i:i + 1, :], jnp.inf)
                    for i in range(n)]
            t = _masked_rank_interp(_sorted_rows(rows), kept)[0, 0]
        else:
            t = tau
        scale = jnp.minimum(1.0, t / jnp.maximum(norm, 1e-12))
        o_ref[...] = v_ref[...] + jnp.sum(
            diff * scale * m, axis=0, keepdims=True) / k


def masked_cc_iter_fwd(updates, v, mask, *, clip_tau=None,
                       block_d: int = 2048, interpret: bool = False):
    """One masked CenteredClip iteration: v ← v + Σᵢ mᵢ·clip(xᵢ − v, τ)/k.
    updates (N, D) f32, v (D,), mask (N,) -> (D,).  ``clip_tau=None``
    selects the adaptive τ (masked median of ‖xᵢ − v‖)."""
    n, d0 = updates.shape
    updates, _ = _pad_lanes(updates)
    v, _ = _pad_lanes(v)
    d = updates.shape[1]
    block_d = _fit_block(d, block_d)
    kern = functools.partial(_cc_kernel, n=n,
                             tau=None if clip_tau is None else float(clip_tau))
    out = pl.pallas_call(
        kern,
        grid=(2, d // block_d),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda ph, j: (0, j)),
            pl.BlockSpec((1, block_d), lambda ph, j: (0, j)),
            pl.BlockSpec((n, 1), lambda ph, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda ph, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, 1), jnp.float32)],
        interpret=interpret,
    )(updates, v.reshape(1, d), mask.reshape(n, 1).astype(jnp.float32))
    return out.reshape(d)[:d0]


# --------------------------- krum distance phase -------------------------------
def _krum_d2_kernel(x_ref, o_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                 # (N, bd)
    sq = jnp.sum(x * x, axis=1)                        # (N,)
    gram = jnp.dot(x, x.T, preferred_element_type=jnp.float32)
    o_ref[...] += sq[:, None] + sq[None, :] - 2.0 * gram


def masked_krum_d2_fwd(updates, *, block_d: int = 2048,
                       interpret: bool = False):
    """Pairwise squared distances (N, N) of the update stack, accumulated
    tile-by-tile in the gram form (one MXU matmul per tile).  The mask and
    +inf/selection semantics are applied by the caller — they are O(N²)
    and touch nothing of size D."""
    n, _ = updates.shape
    updates, _ = _pad_lanes(updates)
    d = updates.shape[1]
    block_d = _fit_block(d, block_d)
    return pl.pallas_call(
        _krum_d2_kernel,
        grid=(d // block_d,),
        in_specs=[pl.BlockSpec((n, block_d), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, n), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(updates)
