"""Pure-jnp oracles — the engine's masked aggregators ARE the reference."""
from __future__ import annotations

from repro.core.aggregation import (  # noqa: F401
    _masked_median as masked_median_ref,
    masked_centered_clip as masked_centered_clip_ref,
    masked_krum as masked_krum_ref,
    masked_mean as masked_mean_ref,
)

import jax.numpy as jnp


def masked_krum_d2_ref(updates):
    """Broadcast-form pairwise squared distances (the reference's d2)."""
    x = updates.astype(jnp.float32)
    return jnp.sum(jnp.square(x[:, None, :] - x[None, :, :]), axis=-1)
