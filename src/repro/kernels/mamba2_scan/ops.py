"""Jit'd SSD wrapper with the same surface as models.mamba2.ssd_chunked."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan.kernel import ssd_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x, dt, a, b, c, d_skip, *, chunk: int = 128, h0=None,
                       interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); a: (H,); b, c: (B, S, N).
    Returns (y (B, S, H, P), h_final (B, H, P, N)) — matches ssd_chunked."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xdt = x.astype(jnp.float32) * dt[..., None]
    adt = a[None, None, :] * dt
    y, hf = ssd_scan_fwd(xdt, adt, b.astype(jnp.float32),
                         c.astype(jnp.float32), h0, chunk=chunk,
                         interpret=interpret)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), hf
