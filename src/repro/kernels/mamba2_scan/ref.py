"""Pure-jnp oracle: the token-by-token SSD recurrence."""
from __future__ import annotations

from repro.models.mamba2 import ssd_reference  # noqa: F401
