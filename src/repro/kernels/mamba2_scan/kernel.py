"""Mamba2 SSD chunked scan — Pallas TPU kernel (zamba2's recurrent core).

One grid cell computes one (batch, head, chunk) tile of the SSD recurrence:

  intra-chunk   M[t,s] = (C_t·B_s) · exp(cs_t − cs_s)   (s ≤ t, banded matmul)
  inter-chunk   y_t   += exp(cs_t) · C_t · h_prev
  state carry   h     ← exp(cs_end) h_prev + Σ_s exp(cs_end − cs_s) B_s ⊗ x_s

TPU adaptation (DESIGN.md §2): the chunk dim is the MXU matmul dim — three
(c×c)/(c×N)/(c×P) matmuls per tile with c a multiple of 128; the running
state h (P×N fp32) lives in VMEM scratch and is carried across the
innermost sequential grid dimension (the chunk index), so HBM traffic is
one read of x/B/C and one write of y per token — the recurrence never
round-trips state through HBM.

Grid: (B·H, n_chunks)   (chunks innermost/sequential — state carry)
Blocks (inputs pre-reshaped to (B, nc, c, ...)):
  x   (1, 1, c, 1, P)   adt (1, 1, c, 1)    b/c (1, 1, c, N)
  h0  (1, 1, P, N)
Outputs: y (1, 1, c, 1, P);  h_final (1, 1, P, N)
Scratch: h (P, N) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hf_ref, h_ref, *,
            nchunks: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, :, 0].astype(jnp.float32)          # (c, P)
    adt = a_ref[0, 0].astype(jnp.float32)              # (c,)
    bm = b_ref[0, 0].astype(jnp.float32)               # (c, N)
    cm = c_ref[0, 0].astype(jnp.float32)               # (c, N)
    cseq = x.shape[0]

    cs = jnp.cumsum(adt)                               # (c,) inclusive
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))   # (c, c)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    tri = (jax.lax.broadcasted_iota(jnp.int32, (cseq, cseq), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (cseq, cseq), 1))
    m = jnp.where(tri, decay, 0.0) * cb
    y = jax.lax.dot(m, x)                              # (c, P) intra
    # inter-chunk: exp(cs_t) · C_t · h_prev    (h: (P, N))
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cm, h_ref[...], (((1,), (1,)), ((), ())))      # (c, N)·(P, N)ᵀ
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # state update
    end = cs[-1]
    w = jnp.exp(end - cs)                              # (c,)
    h_new = h_ref[...] * jnp.exp(end) + jax.lax.dot_general(
        x, bm * w[:, None], (((0,), (0,)), ((), ())))  # (P, N)
    h_ref[...] = h_new

    @pl.when(k == nchunks - 1)
    def _final():
        hf_ref[0, 0] = h_new.astype(hf_ref.dtype)


def ssd_scan_fwd(x, adt, b, c, h0, *, chunk: int = 128,
                 interpret: bool = False):
    """x: (B, S, H, P) Δ-weighted input; adt: (B, S, H) = a·Δ (≤ 0);
    b, c: (B, S, N); h0: (B, H, P, N) fp32.
    Returns y (B, S, H, P) fp32 (no D-skip) and h_final (B, H, P, N) fp32.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    xr = x.reshape(bsz, nc, chunk, h, p)
    ar = adt.reshape(bsz, nc, chunk, h)
    br = b.reshape(bsz, nc, chunk, n)
    cr = c.reshape(bsz, nc, chunk, n)

    grid = (bsz * h, nc)
    kern = functools.partial(_kernel, nchunks=nc)

    y, hf = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, p), lambda bh, k: (bh // h, k, 0, bh % h, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bh, k: (bh // h, k, 0, bh % h)),
            pl.BlockSpec((1, 1, chunk, n), lambda bh, k: (bh // h, k, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bh, k: (bh // h, k, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bh, k: (bh // h, bh % h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, p), lambda bh, k: (bh // h, k, 0, bh % h, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bh, k: (bh // h, bh % h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, chunk, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, ar.reshape(bsz, nc, chunk, h), br, cr, h0.astype(jnp.float32))
    return y.reshape(bsz, s, h, p), hf
