"""Jit'd public wrapper: model layout (B, S, H, hd) in/out."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.kernel import swa_attention_fwd


@functools.partial(jax.jit, static_argnames=("window", "block_q", "interpret"))
def swa_attention(q, k, v, *, window: int, block_q: int = 128,
                  interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) -> (B, S, H, hd)."""
    o = swa_attention_fwd(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        window=window, block_q=block_q, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
