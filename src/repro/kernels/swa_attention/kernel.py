"""Flash-style sliding-window attention — Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §2): instead of the CUDA flash-attention
warp layout, blocks are chosen for the MXU/VMEM hierarchy —
(block_q × head_dim) q tiles resident in VMEM, the kv window streamed in
block_q-sized tiles through the innermost sequential grid dimension with an
online-softmax accumulator in VMEM scratch.  All matmul dims are multiples
of 128 when head_dim is (the assigned archs use hd ∈ {64, 128}).

Grid: (B·H, n_q_blocks, n_window_blocks)   (last dim innermost/sequential)
Block shapes:
  q   (1, 1, bq, hd)   from (B, H, S, hd)
  k/v (1, 1, bq, hd)   from (B, Hkv, S, hd) — GQA folds h→h//G in index_map
  out (1, 1, bq, hd)
Scratch (VMEM): m (bq, 1), l (bq, 1), acc (bq, hd) — fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, block_q: int, num_win_blocks: int, scale: float):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # window block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_block = i - (num_win_blocks - 1) + j               # true kv block index
    q = q_ref[0, 0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bq)

    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 0)
    kpos = kv_block * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 1)
    mask = (qpos >= kpos) & (qpos - kpos < window) & (kv_block >= 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == num_win_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def swa_attention_fwd(q, k, v, *, window: int, block_q: int = 128,
                      interpret: bool = False):
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd).  Causal sliding-window."""
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    block_q = min(block_q, s)
    while s % block_q:
        block_q //= 2
    # kv blocks covering (qpos − window, qpos] for every q in a block:
    # ceil(window / block_q) previous blocks + the diagonal block
    num_win_blocks = -(-window // block_q) + 1
    grid = (b * h, s // block_q, num_win_blocks)
    scale = hd ** -0.5

    def q_map(bh, i, j):
        return (bh // h, bh % h, i, 0)

    def kv_map(bh, i, j):
        kvb = i - (num_win_blocks - 1) + j
        return (bh // h, (bh % h) // g, jnp.maximum(kvb, 0), 0)

    kern = functools.partial(
        _kernel, window=window, block_q=block_q,
        num_win_blocks=num_win_blocks, scale=scale)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), q_map),
            pl.BlockSpec((1, 1, block_q, hd), kv_map),
            pl.BlockSpec((1, 1, block_q, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
