"""Pure-jnp oracle for the SWA kernel (delegates to the model-level math)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import reference_attention


def swa_attention_ref(q, k, v, *, window: int):
    """Same layout as the kernel: q (B, H, S, hd), k/v (B, Hkv, S, hd)."""
    o = reference_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True, window=window)
    return jnp.swapaxes(o, 1, 2)
