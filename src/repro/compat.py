"""Version-compat shims for jax APIs that moved between 0.4.x and 0.6+.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
with two renames on the way: ``check_rep`` became ``check_vma``, and the
partial-manual escape hatch flipped from ``auto`` (axes that stay automatic)
to ``axis_names`` (axes that become manual).  ``lax.axis_size`` / ``lax.pvary``
are new-API-only, and ``compiled.cost_analysis()`` changed its return type
from list-of-dicts to dict.  The container pins jax 0.4.37 (old API); newer
stacks have only the new one — callers use these wrappers and never spell
either.
"""
from __future__ import annotations

from typing import Iterable, Optional

import jax
import jax.numpy as jnp


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Iterable[str]] = None, check: bool = True):
    """Dispatch to whichever shard_map this jax ships.

    ``axis_names`` lists the mesh axes to run manually (None = all of them);
    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old).
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, **kwargs)


def axis_size(axis_name: str) -> int:
    """Static size of a manual mesh axis, inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    # old jax: psum of a Python constant is special-cased to a static int
    return jax.lax.psum(1, axis_name)


def pvary(x, axis_names):
    """Mark ``x`` as varying over ``axis_names`` (new-API replication typing).

    Old jax has no varying-manual-axes annotation — with ``check_rep=False``
    it is simply not needed, so this is the identity there.
    """
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def collectives_emulated() -> bool:
    """True when partial-manual shard_map cannot lower gather/permute
    collectives (old jax: the 0.4.x SPMD partitioner hard-aborts on
    ``all_gather``/``ppermute``/``axis_index`` inside an ``auto`` region —
    only ``psum`` survives)."""
    return not hasattr(jax, "shard_map")


def all_gather(x, axis_name: str, *, index=None):
    """``lax.all_gather`` (result stacked on a new leading axis).

    ``index`` is this shard's position along the axis, derived from *data*
    (an arange sharded over the axis), not ``axis_index`` — old jax cannot
    lower ``axis_index`` in partial-manual mode either.  When emulation is
    needed and ``index`` is given, the gather becomes scatter-into-zeros +
    ``psum`` (each slot has exactly one contributor, so integer dtypes can't
    overflow)."""
    if index is None or not collectives_emulated():
        return jax.lax.all_gather(x, axis_name)
    n = axis_size(axis_name)
    buf = jnp.zeros((n,) + x.shape, x.dtype).at[index].set(x)
    return jax.lax.psum(buf, axis_name)


def ppermute(x, axis_name: str, perm, *, index=None):
    """``lax.ppermute`` with the same psum-based fallback as ``all_gather``.
    Sources without an outgoing edge park their value in a spare slot;
    destinations without an incoming edge read zeros (lax semantics)."""
    if index is None or not collectives_emulated():
        return jax.lax.ppermute(x, axis_name, perm)
    n = axis_size(axis_name)
    dst_of_src = {s: d for s, d in perm}
    dst_table = jnp.asarray([dst_of_src.get(s, n) for s in range(n)], jnp.int32)
    buf = jnp.zeros((n + 1,) + x.shape, x.dtype).at[dst_table[index]].set(x)
    return jax.lax.psum(buf, axis_name)[index]


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax version
    (old jax returns a singleton list of dicts; empty/None when the backend
    reports nothing)."""
    xla = compiled.cost_analysis() or {}
    if isinstance(xla, (list, tuple)):
        xla = xla[0] if xla else {}
    return dict(xla)
